//! Figure-regeneration harnesses — one per table/figure of the paper's
//! evaluation (§5, Appendix A). Each harness builds the paper's workload,
//! runs the (solver × transform) grid, writes the convergence series to
//! `results/*.csv`, and returns the curves for summary printing.
//!
//! Shared protocol (matching §5.1–5.2):
//! * compute ground-truth bottom-k eigenvectors with the dense eigensolver;
//! * build `M = λ*I − f(L)` per transform;
//! * run µ-EG and Oja from the same random init;
//! * record longest eigenvector streak (Figs 2, 4, 5, 6) and normalized
//!   subspace error (Fig 3) over training steps.
//!
//! Step budgets are scaled to the single-core image (`fast` shrinks them
//! further for smoke runs); the paper's qualitative shape — transforms
//! converge about an order of magnitude faster than identity, exact log
//! about two — is what the summaries assert.

use crate::graph::gen::{cliques, CliqueSpec};
use crate::graph::Graph;
use crate::linalg::dmat::DMat;
use crate::linalg::eigh;
use crate::linalg::metrics::ConvergenceHistory;
use crate::solvers::{run_convergence, solver_by_name, DenseOp, RunConfig};
use crate::transforms::{build_solver_matrix, BuildOptions, TransformKind};
use crate::util::csv::CsvWriter;
use anyhow::Result;

/// Options shared by all experiment harnesses.
#[derive(Clone, Debug)]
pub struct ExperimentOptions {
    /// Shrink sizes/budgets for smoke runs (`SPED_BENCH_FAST=1`).
    pub fast: bool,
    /// Output directory for CSV series.
    pub out_dir: String,
    pub seed: u64,
    /// Use the paper's full graph sizes (n=1000/2000) instead of the
    /// single-core-scaled defaults.
    pub full_size: bool,
}

impl Default for ExperimentOptions {
    fn default() -> Self {
        ExperimentOptions {
            fast: crate::util::bench::fast_mode(),
            out_dir: "results".into(),
            seed: 1234,
            full_size: false,
        }
    }
}

/// The paper's Figure 2/3 transform set.
pub fn paper_transforms() -> Vec<TransformKind> {
    vec![
        TransformKind::Identity,
        TransformKind::NegExp,
        TransformKind::LimitNegExp { ell: 251 },
        TransformKind::MatrixLog { eps: 0.05 },
    ]
}

/// Run one (solver × transform) grid on a fixed Laplacian.
///
/// The learning rate is normalized per transform: `η = eta_base / ρ(M)`
/// (with `ρ(M) = λ* − f(0)` analytically), so every run takes comparable
/// step sizes relative to its spectral radius and differences come from the
/// *relative eigengaps* — the quantity SPED manipulates.
pub fn run_grid(
    l: &DMat,
    k: usize,
    transforms: &[TransformKind],
    solvers: &[&str],
    eta_base: f64,
    steps: usize,
    eval_every: usize,
    seed: u64,
) -> Result<Vec<ConvergenceHistory>> {
    let e = eigh(l)?;
    let v_star = e.bottom_k(k);
    let mut out = Vec::new();
    for &t in transforms {
        let sm = build_solver_matrix(l, t, &BuildOptions::default())?;
        let rho_m = (sm.lambda_star - t.scalar_map(0.0)).abs().max(1e-9);
        let eta = eta_base / rho_m;
        for &s in solvers {
            let mut solver = solver_by_name(s, eta)?;
            let mut op = DenseOp::new(sm.m.clone());
            let cfg = RunConfig {
                steps,
                eval_every,
                streak_eps: 1e-2,
                stop_error: 1e-5,
                seed,
                group_values: Some(e.values[..k].to_vec()),
            };
            let mut hist = run_convergence(solver.as_mut(), &mut op, &v_star, &cfg);
            hist.label = format!("{s}|{}", t.name());
            out.push(hist);
        }
    }
    Ok(out)
}

/// Write a curve set as CSV: `label,step,subspace_error,streak`.
pub fn write_curves(path: &str, curves: &[ConvergenceHistory]) -> Result<()> {
    let mut w = CsvWriter::create(path, &["label", "step", "subspace_error", "streak"])?;
    for c in curves {
        for p in &c.points {
            w.row(&[
                c.label.clone(),
                p.step.to_string(),
                format!("{}", p.subspace_error),
                p.streak.to_string(),
            ])?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Summary row: steps to reach streak ≥ target and error ≤ 0.01.
pub fn summarize(curves: &[ConvergenceHistory], streak_target: usize) -> Vec<String> {
    let mut rows = vec![format!(
        "{:<42} {:>14} {:>14} {:>10} {:>8}",
        "curve", "steps→streak", "steps→err<.01", "final err", "streak"
    )];
    for c in curves {
        let s1 = c
            .steps_to_streak(streak_target)
            .map(|s| s.to_string())
            .unwrap_or_else(|| "-".into());
        let s2 = c
            .steps_to_error(0.01)
            .map(|s| s.to_string())
            .unwrap_or_else(|| "-".into());
        let last = c.last().unwrap();
        rows.push(format!(
            "{:<42} {:>14} {:>14} {:>10.2e} {:>8}",
            c.label, s1, s2, last.subspace_error, last.streak
        ));
    }
    rows
}

// ---------------------------------------------------------------------------
// Figure 2 & 3: 3-room MDP proto-value functions
// ---------------------------------------------------------------------------

/// Figures 2 (streak) and 3 (subspace error) share one run: the 3-room MDP
/// with µ-EG and Oja across the four transforms.
pub fn fig2_fig3_mdp(opts: &ExperimentOptions) -> Result<Vec<ConvergenceHistory>> {
    let spec = if opts.fast {
        crate::mdp::ThreeRoomSpec { s: 1, h: 10 }
    } else {
        crate::mdp::ThreeRoomSpec { s: 1, h: 10 }
    };
    let world = crate::mdp::GridWorld::three_rooms(spec)?;
    let l = world.graph.laplacian();
    let k = 8;
    let (steps, every) = if opts.fast { (2_000, 50) } else { (40_000, 200) };
    let curves = run_grid(
        &l,
        k,
        &paper_transforms(),
        &["mu-eg", "oja"],
        0.5,
        steps,
        every,
        opts.seed,
    )?;
    write_curves(&format!("{}/fig2_fig3_mdp.csv", opts.out_dir), &curves)?;
    Ok(curves)
}

// ---------------------------------------------------------------------------
// Figure 4: well-clustered clique graphs
// ---------------------------------------------------------------------------

/// One Figure-4 panel: n nodes, c cliques.
pub fn fig4_panel(
    n: usize,
    c: usize,
    opts: &ExperimentOptions,
) -> Result<Vec<ConvergenceHistory>> {
    let gg = cliques(&CliqueSpec { n, k: c, max_short_circuit: 25, seed: opts.seed });
    let l = gg.graph.laplacian();
    let (steps, every) = if opts.fast { (1_500, 50) } else { (20_000, 100) };
    let mut curves = run_grid(
        &l,
        c.max(2),
        &paper_transforms(),
        &["mu-eg", "oja"],
        0.5,
        steps,
        every,
        opts.seed,
    )?;
    for h in &mut curves {
        h.label = format!("n{n}_c{c}|{}", h.label);
    }
    Ok(curves)
}

/// Figure 4 grid. Paper: n ∈ {1000, 2000} × clusters ∈ {2, 3, 5}; scaled
/// default n ∈ {192, 384} (single core) unless `full_size`.
pub fn fig4_cliques(opts: &ExperimentOptions) -> Result<Vec<ConvergenceHistory>> {
    let sizes: Vec<usize> = if opts.full_size {
        vec![1000, 2000]
    } else if opts.fast {
        vec![96]
    } else {
        vec![192, 384]
    };
    let clusters = if opts.fast { vec![2, 5] } else { vec![2, 3, 5] };
    let mut all = Vec::new();
    for &n in &sizes {
        for &c in &clusters {
            all.extend(fig4_panel(n, c, opts)?);
        }
    }
    write_curves(&format!("{}/fig4_cliques.csv", opts.out_dir), &all)?;
    Ok(all)
}

// ---------------------------------------------------------------------------
// Figure 5: link-prediction-completed probabilistic graphs
// ---------------------------------------------------------------------------

pub fn fig5_linkpred(opts: &ExperimentOptions) -> Result<Vec<ConvergenceHistory>> {
    let (n, c) = if opts.fast { (96, 3) } else { (240, 3) };
    let gg = cliques(&CliqueSpec { n, k: c, max_short_circuit: 10, seed: opts.seed });
    let dropped = crate::linkpred::drop_edges(&gg.graph, 0.2, opts.seed ^ 0xA1)?;
    let completed = crate::linkpred::complete_graph(&dropped)?;
    let l = completed.laplacian();
    let (steps, every) = if opts.fast { (1_500, 50) } else { (20_000, 100) };
    let mut curves = run_grid(
        &l,
        c,
        &paper_transforms(),
        &["mu-eg", "oja"],
        0.5,
        steps,
        every,
        opts.seed,
    )?;
    for h in &mut curves {
        h.label = format!("linkpred|{}", h.label);
    }
    write_curves(&format!("{}/fig5_linkpred.csv", opts.out_dir), &curves)?;
    Ok(curves)
}

// ---------------------------------------------------------------------------
// Figure 6: series-degree sweep
// ---------------------------------------------------------------------------

/// Figure 6: vary the number of series terms ℓ across the three series
/// families (limit −e^{−L}, Taylor −e^{−L}, Taylor log).
pub fn fig6_series_terms(opts: &ExperimentOptions) -> Result<Vec<ConvergenceHistory>> {
    let (n, c) = if opts.fast { (96, 3) } else { (240, 3) };
    let gg = cliques(&CliqueSpec { n, k: c, max_short_circuit: 10, seed: opts.seed });
    let l = gg.graph.laplacian();
    let ells = [11usize, 51, 151, 251];
    let mut transforms = Vec::new();
    for &ell in &ells {
        transforms.push(TransformKind::LimitNegExp { ell });
        transforms.push(TransformKind::TaylorNegExp { ell });
    }
    // Taylor-log requires ρ(L+εI−I) < 1 — prescaled variant is evaluated
    // separately in the ablation; at raw scale it diverges (the paper's
    // §5.3 finding). Include it to *show* the failure.
    transforms.push(TransformKind::TaylorLog { ell: 251, eps: 0.05 });
    let (steps, every) = if opts.fast { (1_500, 50) } else { (15_000, 100) };
    let curves = run_grid(
        &l,
        c,
        &transforms,
        &["mu-eg", "oja"],
        0.5,
        steps,
        every,
        opts.seed,
    )?;
    write_curves(&format!("{}/fig6_series_terms.csv", opts.out_dir), &curves)?;
    Ok(curves)
}

// ---------------------------------------------------------------------------
// Walk-estimator experiment (§4.3 claims)
// ---------------------------------------------------------------------------

/// §4.3 validation: estimator error vs number of walks, rejection vs
/// importance; acceptance rate vs walk length. Returns printable rows.
pub fn walk_estimator_experiment(opts: &ExperimentOptions) -> Result<Vec<String>> {
    use crate::walks::{estimate_l_power, SampleMethod};
    let g = cliques(&CliqueSpec { n: 24, k: 3, max_short_circuit: 3, seed: opts.seed }).graph;
    let l = g.laplacian();
    let l2 = crate::linalg::matmul::matmul(&l, &l);
    let l3 = crate::linalg::matmul::matmul(&l2, &l);
    let mut rows = vec![format!(
        "{:<12} {:>6} {:>10} {:>12} {:>12}",
        "method", "len", "walks", "rel_err", "accept_rate"
    )];
    let budgets: &[usize] = if opts.fast {
        &[2_000, 8_000]
    } else {
        &[2_000, 8_000, 32_000, 128_000]
    };
    let mut csv = CsvWriter::create(
        &format!("{}/walk_estimator.csv", opts.out_dir),
        &["method", "len", "walks", "rel_err", "accept_rate"],
    )?;
    for method in [SampleMethod::Rejection, SampleMethod::Importance] {
        for (len, truth) in [(2usize, &l2), (3usize, &l3)] {
            for &walks in budgets {
                let (est, stats) =
                    estimate_l_power(&g, len, walks, 4, method, opts.seed ^ walks as u64);
                let err = (&est - truth).max_abs() / truth.max_abs();
                rows.push(format!(
                    "{:<12} {:>6} {:>10} {:>12.4} {:>12.4}",
                    format!("{method:?}"),
                    len,
                    walks,
                    err,
                    stats.acceptance_rate()
                ));
                csv.row(&[
                    format!("{method:?}"),
                    len.to_string(),
                    walks.to_string(),
                    format!("{err}"),
                    format!("{}", stats.acceptance_rate()),
                ])?;
            }
        }
    }
    csv.flush()?;
    Ok(rows)
}

/// Spectrum diagnostics used by the figure summaries: relative gap ratios
/// before/after each paper transform on a given Laplacian.
pub fn gap_report(l: &DMat, k: usize) -> Result<Vec<String>> {
    let e = eigh(l)?;
    let mut rows = vec![format!(
        "{:<28} {:>14} {:>14}",
        "transform", "max ρ/g (bot-k)", "improvement"
    )];
    let base = crate::transforms::gap_ratios(&e.values, k)
        .into_iter()
        .fold(0.0f64, f64::max);
    for t in paper_transforms() {
        let mapped: Vec<f64> = e.values.iter().map(|&x| t.scalar_map(x)).collect();
        let ratio = crate::transforms::gap_ratios(&mapped, k)
            .into_iter()
            .fold(0.0f64, f64::max);
        rows.push(format!(
            "{:<28} {:>14.1} {:>13.1}x",
            t.name(),
            ratio,
            base / ratio.max(1e-12)
        ));
    }
    Ok(rows)
}

/// Graph helper for CLI/bench reuse.
pub fn load_or_generate(kind: &str, n: usize, c: usize, seed: u64) -> Result<Graph> {
    Ok(match kind {
        "cliques" => cliques(&CliqueSpec { n, k: c, max_short_circuit: 25, seed }).graph,
        "mdp" => crate::mdp::GridWorld::three_rooms(crate::mdp::ThreeRoomSpec::default())?.graph,
        "sbm" => {
            crate::graph::gen::sbm(&vec![n / c.max(1); c.max(1)], 0.8, 0.02, seed).graph
        }
        path => crate::graph::io::load_edge_list(path)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_opts() -> ExperimentOptions {
        ExperimentOptions {
            fast: true,
            out_dir: std::env::temp_dir()
                .join("sped_exp_test")
                .to_string_lossy()
                .into_owned(),
            seed: 3,
            full_size: false,
        }
    }

    #[test]
    fn grid_produces_labeled_curves() {
        let g = cliques(&CliqueSpec { n: 24, k: 2, max_short_circuit: 1, seed: 1 }).graph;
        let curves = run_grid(
            &g.laplacian(),
            2,
            &[TransformKind::Identity, TransformKind::NegExp],
            &["oja"],
            0.5,
            300,
            50,
            7,
        )
        .unwrap();
        assert_eq!(curves.len(), 2);
        assert!(curves[0].label.contains("identity"));
        assert!(curves[1].label.contains("exp"));
    }

    #[test]
    fn transforms_beat_identity_in_miniature() {
        // The Figure-4 shape at test scale: steps-to-convergence under the
        // exact −e^{−L} must be clearly smaller than identity on a hard
        // instance (large cliques → λ_max ≫ bottom gaps).
        let g = cliques(&CliqueSpec { n: 60, k: 3, max_short_circuit: 4, seed: 17 }).graph;
        let curves = run_grid(
            &g.laplacian(),
            3,
            &[TransformKind::Identity, TransformKind::NegExp],
            &["oja"],
            0.5,
            20_000,
            10,
            9,
        )
        .unwrap();
        // Streak (ordered eigenvectors) is the paper's discriminating
        // metric — it requires resolving the tiny bottom gaps.
        let sid = curves[0].steps_to_streak(3).unwrap_or(usize::MAX);
        let sexp = curves[1].steps_to_streak(3).unwrap_or(usize::MAX);
        assert!(sexp * 2 <= sid, "identity {sid} vs negexp {sexp}");
    }

    #[test]
    fn walk_experiment_rows() {
        let rows = walk_estimator_experiment(&fast_opts()).unwrap();
        assert!(rows.len() > 4);
        std::fs::remove_dir_all(fast_opts().out_dir).ok();
    }

    #[test]
    fn gap_report_shows_improvement() {
        let g = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 7 }).graph;
        let rows = gap_report(&g.laplacian(), 3).unwrap();
        assert_eq!(rows.len(), 1 + paper_transforms().len());
    }

    #[test]
    fn csv_written() {
        let opts = fast_opts();
        std::fs::create_dir_all(&opts.out_dir).unwrap();
        let g = cliques(&CliqueSpec { n: 20, k: 2, max_short_circuit: 1, seed: 2 }).graph;
        let curves = run_grid(
            &g.laplacian(),
            2,
            &[TransformKind::NegExp],
            &["oja"],
            0.5,
            100,
            50,
            1,
        )
        .unwrap();
        let path = format!("{}/test.csv", opts.out_dir);
        write_curves(&path, &curves).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("label,step,subspace_error,streak"));
        assert!(text.lines().count() > 2);
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }
}
