//! Long-lived embedding service: solve rarely, serve constantly.
//!
//! The north-star workload reads embeddings far more often than it solves
//! for them. A [`ServeSession`] owns a mutable [`Graph`] plus one cached
//! embedding keyed by **(graph content hash, transform/solver config
//! fingerprint)** and answers batched queries against it:
//!
//! * `linkpred U V` — link-prediction score for a candidate pair, the
//!   embedding-space analogue of the `linkpred/` common-neighbors score
//!   (cosine of the row-normalized embedding rows);
//! * `cluster U` — nearest-cluster lookup against the k-means centroids;
//! * `topk U K` — the K most similar nodes by embedding cosine.
//!
//! One batch evaluates many queries in a single pass over the cached
//! [`DMat`]: the batch is validated up front, the cache key is checked
//! **once** (an `O(E)` content hash — the cost batching amortizes), and the
//! answer slots are row-sharded across workers via the same
//! `linalg::par` partition the dense kernels use. Each shard answers its
//! queries with the unchanged serial kernel, so a batch's answers are
//! **bitwise identical for every worker count** — the repo-wide
//! determinism contract.
//!
//! Delta ingestion reuses the `sped stream` event grammar
//! ([`crate::coordinator::stream::parse_event_batches`]) and invalidates
//! exactly per the [`DeltaOutcome`] flags: a weights-only batch keeps the
//! cached RCM order (topology artifact) and drops only the embedding; a
//! topology batch drops both. The re-solve is **lazy** — it runs on the
//! next query after invalidation, warm-started from the previous
//! embedding under the same churn policy [`StreamSession`] uses.
//!
//! [`StreamSession`]: crate::coordinator::stream::StreamSession

use crate::cluster::{nearest_centroid, row_normalize};
use crate::coordinator::pipeline::{
    Pipeline, PipelineConfig, RitzSummary, SolvePath, RITZ_HISTORY_CAP,
};
use crate::graph::delta::{DeltaOutcome, EdgeDelta};
use crate::graph::{Graph, Reorder};
use crate::linalg::dmat::DMat;
use crate::linalg::par::{row_shards, shard_starts};
use crate::linkpred::embedding_score;
use crate::util::pool::parallel_shards;
use anyhow::{bail, Context, Result};

/// Serve-session configuration: the pipeline a (re-)solve runs plus the
/// warm/cold degradation policy — the same knobs as
/// [`crate::coordinator::stream::StreamConfig`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// The pipeline each lazy re-solve runs. `warm_start`, `rcm_order`,
    /// and `do_cluster` are managed by the session (anything set here is
    /// overwritten; clustering is always on — nearest-cluster queries
    /// need the centroids).
    pub pipeline: PipelineConfig,
    /// Churn fraction above which a re-solve runs cold instead of
    /// warm-starting from the previous embedding (`--solver ritz` only).
    pub warm_volume_frac: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { pipeline: PipelineConfig::default(), warm_volume_frac: 0.25 }
    }
}

/// One query against the cached embedding. Text grammar (one per line in
/// a query file, `---` closes a batch): `linkpred U V`, `cluster U`,
/// `topk U K`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Query {
    /// Link-prediction score for the candidate pair `(u, v)`.
    LinkPred { u: usize, v: usize },
    /// Nearest k-means cluster of node `u`.
    NearestCluster { u: usize },
    /// The `k` nodes most similar to `u` (self excluded).
    TopK { u: usize, k: usize },
}

impl Query {
    /// Parse one query line. Errors name the token that failed.
    pub fn parse(line: &str) -> Result<Query> {
        let mut it = line.split_whitespace();
        let kind = it.next().context("empty query")?;
        let mut num = |name: &str| -> Result<usize> {
            let tok = it.next().with_context(|| format!("{kind}: missing {name}"))?;
            tok.parse::<usize>()
                .with_context(|| format!("{kind}: bad {name} {tok:?}"))
        };
        let q = match kind {
            "linkpred" => Query::LinkPred { u: num("u")?, v: num("v")? },
            "cluster" => Query::NearestCluster { u: num("u")? },
            "topk" => Query::TopK { u: num("u")?, k: num("k")? },
            other => bail!("unknown query kind {other:?} (linkpred | cluster | topk)"),
        };
        if let Some(extra) = it.next() {
            bail!("{kind}: unexpected trailing token {extra:?}");
        }
        Ok(q)
    }

    /// Bounds-check against a graph of `n` nodes. `idx` is the position
    /// in the batch, for the error message — a bad batch must surface a
    /// query-numbered error, never a panic.
    fn validate(&self, idx: usize, n: usize) -> Result<()> {
        let check = |node: usize| -> Result<()> {
            if node >= n {
                bail!("query {idx}: node {node} out of range (n={n})");
            }
            Ok(())
        };
        match *self {
            Query::LinkPred { u, v } => {
                check(u)?;
                check(v)?;
                if u == v {
                    bail!("query {idx}: linkpred needs two distinct nodes, got {u} twice");
                }
            }
            Query::NearestCluster { u } => check(u)?,
            Query::TopK { u, k } => {
                check(u)?;
                if k == 0 {
                    bail!("query {idx}: topk needs k >= 1");
                }
            }
        }
        Ok(())
    }
}

/// Answer to one [`Query`], in batch order.
#[derive(Clone, Debug, PartialEq)]
pub enum Answer {
    /// `linkpred`: cosine similarity of the row-normalized embedding rows
    /// (in `[-1, 1]`; zero rows score 0).
    Score(f64),
    /// `cluster`: the nearest centroid and the Euclidean distance to it.
    Cluster { cluster: usize, distance: f64 },
    /// `topk`: `(node, score)` descending by score, ties broken by
    /// ascending node id (a total order — deterministic).
    Neighbors(Vec<(usize, f64)>),
}

/// Parse a query file into batches: one query per line, blank lines and
/// `#` comments skipped, a `---` line closes the current batch. Errors
/// carry the 1-based line number (the same framing
/// [`crate::coordinator::stream::parse_event_batches`] uses for deltas).
pub fn parse_query_batches(text: &str) -> Result<Vec<Vec<Query>>> {
    let mut batches: Vec<Vec<Query>> = Vec::new();
    let mut current: Vec<Query> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "---" {
            if current.is_empty() {
                bail!("line {}: empty query batch before `---`", lineno + 1);
            }
            batches.push(std::mem::take(&mut current));
            continue;
        }
        let q = Query::parse(line).with_context(|| format!("line {}", lineno + 1))?;
        current.push(q);
    }
    if !current.is_empty() {
        batches.push(current);
    }
    Ok(batches)
}

/// FNV-1a content hash of a graph: node count plus every canonical edge
/// `(u, v, w)` with the weight hashed bitwise. Two graphs hash equal iff
/// their canonical edge lists are bitwise identical — the graph half of
/// the embedding cache key.
pub fn graph_content_hash(g: &Graph) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut mix = |h: &mut u64, x: u64| {
        for byte in x.to_le_bytes() {
            *h ^= byte as u64;
            *h = h.wrapping_mul(PRIME);
        }
    };
    mix(&mut h, g.num_nodes() as u64);
    for e in g.edges() {
        mix(&mut h, e.u as u64);
        mix(&mut h, e.v as u64);
        mix(&mut h, e.w.to_bits());
    }
    h
}

/// The transform/solver half of the cache key: every config knob that can
/// change the solved embedding. Threads are deliberately excluded — the
/// determinism contract makes the embedding worker-count-invariant — and
/// so is the shard count (`--shards`): the sharded matrix-free operator
/// is bitwise-equal to the unsharded one at every shard count, so it can
/// never change the embedding a cache entry holds.
pub fn config_fingerprint(p: &PipelineConfig) -> String {
    format!(
        "{}|{}|k={}|{}|basis={}|domain={}|degree={}|prescale={}|seed={}|reorder={}",
        p.transform,
        p.solver,
        p.k,
        p.op_mode,
        p.build.basis,
        p.build.domain,
        p.build.degree,
        p.build.prescale,
        p.seed,
        match p.reorder {
            Reorder::Rcm => "rcm",
            Reorder::None => "none",
        },
    )
}

/// The cached derived state one solve produces — everything a query batch
/// reads, so a batch touches no solver code at all on a cache hit.
struct CachedEmbedding {
    /// [`graph_content_hash`] of the graph this embedding was solved on.
    graph_hash: u64,
    /// The raw `n×k` embedding (input node order).
    embedding: DMat,
    /// Row-normalized embedding — the similarity space every query kind
    /// scores in (centroids live here too; see [`crate::cluster`]).
    norm_rows: DMat,
    /// Hard cluster assignments.
    assignments: Vec<usize>,
    /// k-means centroids in the row-normalized space.
    centroids: DMat,
    /// Which solve produced this embedding (cold / warm / warm-degraded).
    path: SolvePath,
}

/// A long-lived serving session over one mutable graph: the cached
/// embedding answers query batches; delta batches invalidate it exactly
/// per the [`DeltaOutcome`] flags; the next query after invalidation
/// re-solves lazily (warm-started when the churn allows).
pub struct ServeSession {
    graph: Graph,
    cfg: ServeConfig,
    fingerprint: String,
    cache: Option<CachedEmbedding>,
    /// Warm-start seed: survives cache invalidation (a stale embedding is
    /// a bad *answer* but a good *seed* under the churn threshold).
    prev_embedding: Option<DMat>,
    /// RCM order for the current topology — kept across weights-only
    /// deltas, dropped on topology changes (same policy as
    /// [`crate::coordinator::stream::StreamSession`]).
    cached_order: Option<Vec<usize>>,
    /// Edge volume accumulated since the last solve.
    delta_volume: usize,
    /// Diagnostics of the most recent `ritz` re-solve, histories capped to
    /// the trailing [`RITZ_HISTORY_CAP`] entries so a long-lived session's
    /// memory stays bounded no matter how many iterations each solve ran.
    last_ritz: Option<RitzSummary>,
    solves: usize,
}

impl ServeSession {
    pub fn new(graph: Graph, cfg: ServeConfig) -> ServeSession {
        let fingerprint = config_fingerprint(&cfg.pipeline);
        ServeSession {
            graph,
            cfg,
            fingerprint,
            cache: None,
            prev_embedding: None,
            cached_order: None,
            delta_volume: 0,
            last_ritz: None,
            solves: 0,
        }
    }

    /// Start from a graph loaded with a persisted `# order:` header: the
    /// stored order seeds the cache and is reused until the first
    /// topology change.
    pub fn with_order(graph: Graph, order: Option<Vec<usize>>, cfg: ServeConfig) -> ServeSession {
        let mut s = ServeSession::new(graph, cfg);
        s.cached_order = order;
        s
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Solves run so far (lazy — one per cache miss, not per batch).
    pub fn solves(&self) -> usize {
        self.solves
    }

    /// Capped diagnostics of the most recent `ritz` re-solve (`None`
    /// before the first one, or with a step-driven solver).
    /// `residual_history` / `locked_history` hold at most
    /// [`RITZ_HISTORY_CAP`] trailing entries; `residual_history_total` and
    /// the sweep counters stay uncapped.
    pub fn last_ritz(&self) -> Option<&RitzSummary> {
        self.last_ritz.as_ref()
    }

    /// The config half of the cache key.
    pub fn fingerprint(&self) -> &str {
        &self.fingerprint
    }

    /// Whether the next query batch will be answered from cache.
    pub fn cache_valid(&self) -> bool {
        self.cache.is_some()
    }

    /// The cached RCM order, if still valid for the current topology.
    pub fn cached_order(&self) -> Option<&[usize]> {
        self.cached_order.as_deref()
    }

    /// Embedding backing the cache (input node order), if valid.
    pub fn embedding(&self) -> Option<&DMat> {
        self.cache.as_ref().map(|c| &c.embedding)
    }

    /// Which path the most recent solve took, if any solve ran.
    pub fn last_solve_path(&self) -> Option<SolvePath> {
        self.cache.as_ref().map(|c| c.path)
    }

    /// Apply one transactional delta batch and invalidate exactly what
    /// the outcome flags say broke: topology → order + embedding,
    /// weights-only → embedding (the order is a topology artifact). A
    /// rejected batch leaves the graph and every cache untouched.
    pub fn apply_batch(&mut self, deltas: &[EdgeDelta]) -> Result<DeltaOutcome> {
        let outcome = self.graph.apply_deltas(deltas)?;
        self.delta_volume += outcome.volume();
        if outcome.topology_changed {
            self.cached_order = None;
        }
        if outcome.topology_changed || outcome.weights_changed {
            self.cache = None;
        }
        Ok(outcome)
    }

    /// Answer a query batch against the cached embedding, re-solving
    /// first iff the cache is invalid (lazy re-solve). The batch is
    /// validated up front — a bad query errors with its batch index and
    /// leaves the session untouched; it never panics. Answers are in
    /// batch order and **bitwise identical for every
    /// `pipeline.threads`** value: the answer slots are row-sharded and
    /// each shard runs the same serial per-query kernel.
    pub fn answer_batch(&mut self, queries: &[Query]) -> Result<Vec<Answer>> {
        let n = self.graph.num_nodes();
        for (idx, q) in queries.iter().enumerate() {
            q.validate(idx, n)?;
        }
        self.ensure_embedding()?;
        let cache = self.cache.as_ref().expect("ensure_embedding filled the cache");
        let threads = self.cfg.pipeline.threads.max(1);
        let mut answers = vec![Answer::Score(0.0); queries.len()];
        let shards = row_shards(queries.len(), threads);
        if shards.len() <= 1 {
            for (slot, q) in answers.iter_mut().zip(queries.iter()) {
                *slot = answer_one(cache, q);
            }
        } else {
            let starts = shard_starts(&shards);
            parallel_shards(&mut answers, &shards, |idx, chunk| {
                let q0 = starts[idx];
                for (j, slot) in chunk.iter_mut().enumerate() {
                    *slot = answer_one(cache, &queries[q0 + j]);
                }
            });
        }
        Ok(answers)
    }

    /// Make the cache valid for the current graph: a hit is one
    /// `O(E)` content-hash check (the per-batch cost batching amortizes);
    /// a miss runs the pipeline, warm-started from the previous
    /// embedding under the same churn policy
    /// [`crate::coordinator::stream::StreamSession::publish`] applies —
    /// including the always-cold rule for zero-edge graphs.
    fn ensure_embedding(&mut self) -> Result<()> {
        let hash = graph_content_hash(&self.graph);
        if self.cache.as_ref().map(|c| c.graph_hash) == Some(hash) {
            return Ok(());
        }
        let volume_frac = self.delta_volume as f64 / self.graph.num_edges().max(1) as f64;
        let mut pcfg = self.cfg.pipeline.clone();
        // Nearest-cluster queries need the centroids unconditionally.
        pcfg.do_cluster = true;
        let force_cold = self.cfg.pipeline.solver != "ritz"
            || self.prev_embedding.is_none()
            || self.graph.num_edges() == 0
            || volume_frac > self.cfg.warm_volume_frac;
        pcfg.warm_start = if force_cold { None } else { self.prev_embedding.clone() };
        if pcfg.reorder == Reorder::Rcm {
            // One RCM rebuild per topology change, not per solve.
            let order = match self.cached_order.take() {
                Some(o) => o,
                None => self.graph.rcm_permutation(),
            };
            pcfg.rcm_order = Some(order.clone());
            self.cached_order = Some(order);
        } else {
            pcfg.rcm_order = None;
        }
        let out = Pipeline::new(pcfg).run(&self.graph).context("serve re-solve")?;
        let path = out.ritz.as_ref().map(|rz| rz.path).unwrap_or(SolvePath::Cold);
        if let Some(rz) = out.ritz.clone() {
            self.last_ritz = Some(rz.capped(RITZ_HISTORY_CAP));
        }
        let clustering = out
            .clustering
            .context("serve re-solve produced no clustering (do_cluster forced on)")?;
        let norm_rows = row_normalize(&out.embedding);
        self.prev_embedding = Some(out.embedding.clone());
        self.delta_volume = 0;
        self.solves += 1;
        self.cache = Some(CachedEmbedding {
            graph_hash: hash,
            embedding: out.embedding,
            norm_rows,
            assignments: clustering.assignments,
            centroids: clustering.centroids,
            path,
        });
        Ok(())
    }
}

/// The serial per-query kernel every shard runs — answers depend only on
/// the cached state and the query, never on the partition.
fn answer_one(cache: &CachedEmbedding, q: &Query) -> Answer {
    match *q {
        Query::LinkPred { u, v } => Answer::Score(embedding_score(&cache.norm_rows, u, v)),
        Query::NearestCluster { u } => {
            let (cluster, d2) = nearest_centroid(&cache.centroids, cache.norm_rows.row(u));
            debug_assert_eq!(
                cluster, cache.assignments[u],
                "nearest centroid must agree with the solved assignment"
            );
            Answer::Cluster { cluster, distance: d2.sqrt() }
        }
        Query::TopK { u, k } => {
            let n = cache.norm_rows.rows();
            let mut scored: Vec<(usize, f64)> = Vec::with_capacity(n.saturating_sub(1));
            for v in 0..n {
                if v != u {
                    scored.push((v, embedding_score(&cache.norm_rows, u, v)));
                }
            }
            // Total order: score descending, node id ascending on ties.
            scored.sort_by(|a, b| {
                b.1.partial_cmp(&a.1)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.0.cmp(&b.0))
            });
            scored.truncate(k);
            Answer::Neighbors(scored)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{cliques, CliqueSpec};
    use crate::transforms::{OpMode, TransformKind};

    fn ritz_serve_cfg(k: usize) -> ServeConfig {
        ServeConfig {
            pipeline: PipelineConfig {
                k,
                transform: TransformKind::LimitNegExp { ell: 51 },
                solver: "ritz".into(),
                ritz_tol: 1e-8,
                ritz_max_iters: 400,
                op_mode: OpMode::MatrixFree,
                ground_truth: false,
                ..Default::default()
            },
            warm_volume_frac: 0.25,
        }
    }

    #[test]
    fn query_grammar_parses_and_rejects() {
        assert_eq!(Query::parse("linkpred 3 7").unwrap(), Query::LinkPred { u: 3, v: 7 });
        assert_eq!(Query::parse("cluster 5").unwrap(), Query::NearestCluster { u: 5 });
        assert_eq!(Query::parse("topk 2 10").unwrap(), Query::TopK { u: 2, k: 10 });
        assert!(Query::parse("linkpred 3").is_err());
        assert!(Query::parse("cluster x").is_err());
        assert!(Query::parse("topk 1 2 3").is_err());
        assert!(Query::parse("nonsense 1 2").is_err());
        let batches =
            parse_query_batches("# warm-up\nlinkpred 0 1\ncluster 2\n---\ntopk 0 3\n").unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].len(), 2);
        let err = parse_query_batches("cluster 0\n---\n---\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 3"), "{err:#}");
        let err = parse_query_batches("linkpred 0\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 1"), "{err:#}");
    }

    #[test]
    fn content_hash_tracks_bitwise_edge_changes() {
        let gg = cliques(&CliqueSpec { n: 24, k: 2, max_short_circuit: 1, seed: 3 });
        let h0 = graph_content_hash(&gg.graph);
        assert_eq!(h0, graph_content_hash(&gg.graph.clone()), "hash is content-only");
        let mut g = gg.graph.clone();
        let (u, v, w) = {
            let e = &g.edges()[0];
            (e.u as usize, e.v as usize, e.w)
        };
        g.apply_deltas(&[EdgeDelta::Reweight { u, v, w: w * 2.0 }]).unwrap();
        assert_ne!(h0, graph_content_hash(&g), "reweight must move the hash");
        // A bitwise round-trip restores the original hash.
        g.apply_deltas(&[EdgeDelta::Reweight { u, v, w }]).unwrap();
        assert_eq!(h0, graph_content_hash(&g));
    }

    #[test]
    fn bad_batches_error_without_solving_or_panicking() {
        let gg = cliques(&CliqueSpec { n: 24, k: 2, max_short_circuit: 1, seed: 3 });
        let mut s = ServeSession::new(gg.graph, ritz_serve_cfg(2));
        let err = s.answer_batch(&[Query::NearestCluster { u: 99 }]).unwrap_err();
        assert!(format!("{err:#}").contains("query 0"), "{err:#}");
        let err = s
            .answer_batch(&[Query::NearestCluster { u: 0 }, Query::LinkPred { u: 5, v: 5 }])
            .unwrap_err();
        assert!(format!("{err:#}").contains("query 1"), "{err:#}");
        let err = s.answer_batch(&[Query::TopK { u: 0, k: 0 }]).unwrap_err();
        assert!(format!("{err:#}").contains("k >= 1"), "{err:#}");
        // Validation runs before the solve: nothing was computed yet.
        assert_eq!(s.solves(), 0);
        assert!(!s.cache_valid());
    }

    #[test]
    fn session_retains_capped_ritz_diagnostics() {
        // Same construction as the stream-session cap test: tol 0 on a
        // full-precision operator never certifies, the default stagnation
        // window (100) exceeds max_iters, so the solve runs exactly 80
        // iterations and the retained summary must hold only the trailing
        // window with honest totals.
        let gg = cliques(&CliqueSpec { n: 24, k: 2, max_short_circuit: 1, seed: 3 });
        let mut cfg = ritz_serve_cfg(2);
        cfg.pipeline.ritz_tol = 0.0;
        cfg.pipeline.ritz_max_iters = 80;
        let mut s = ServeSession::new(gg.graph, cfg);
        assert!(s.last_ritz().is_none(), "no solve yet");
        s.answer_batch(&[Query::LinkPred { u: 0, v: 1 }]).unwrap();
        assert_eq!(s.solves(), 1);
        let rz = s.last_ritz().expect("ritz solve retains a summary");
        assert_eq!(rz.iterations, 80);
        assert!(!rz.converged);
        assert_eq!(rz.residual_history.len(), RITZ_HISTORY_CAP);
        assert_eq!(rz.locked_history.len(), RITZ_HISTORY_CAP);
        assert_eq!(rz.residual_history_total, 80);
        assert_eq!(rz.total_sweeps, 80 * rz.sweeps_per_apply);
        // A cache hit does not re-solve, so the summary stays put.
        s.answer_batch(&[Query::LinkPred { u: 0, v: 1 }]).unwrap();
        assert_eq!(s.solves(), 1);
        assert_eq!(s.last_ritz().unwrap().iterations, 80);
    }

    #[test]
    fn lazy_solve_once_then_cache_hits() {
        let gg = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 9 });
        let mut s = ServeSession::new(gg.graph.clone(), ritz_serve_cfg(3));
        assert!(!s.cache_valid());
        let a1 = s.answer_batch(&[Query::LinkPred { u: 0, v: 1 }]).unwrap();
        assert_eq!(s.solves(), 1);
        assert_eq!(s.last_solve_path(), Some(SolvePath::Cold));
        // Same-clique pair scores near 1, cross-clique near orthogonal.
        let same = match a1[0] {
            Answer::Score(x) => x,
            ref other => panic!("expected score, got {other:?}"),
        };
        assert!(same > 0.9, "same-clique cosine {same}");
        // Every further batch is a cache hit: no extra solves.
        let a2 = s
            .answer_batch(&[
                Query::LinkPred { u: 0, v: 1 },
                Query::NearestCluster { u: 0 },
                Query::TopK { u: 0, k: 5 },
            ])
            .unwrap();
        assert_eq!(s.solves(), 1);
        assert_eq!(a1[0], a2[0], "cache hit must repeat the exact answer");
        match &a2[2] {
            Answer::Neighbors(nb) => {
                assert_eq!(nb.len(), 5);
                for w in nb.windows(2) {
                    assert!(
                        w[0].1 > w[1].1 || (w[0].1 == w[1].1 && w[0].0 < w[1].0),
                        "top-k must be strictly ordered: {nb:?}"
                    );
                }
            }
            other => panic!("expected neighbors, got {other:?}"),
        }
    }

    #[test]
    fn fingerprint_covers_solver_config() {
        let a = ritz_serve_cfg(3);
        let mut b = ritz_serve_cfg(3);
        b.pipeline.k = 4;
        assert_ne!(config_fingerprint(&a.pipeline), config_fingerprint(&b.pipeline));
        let mut c = ritz_serve_cfg(3);
        c.pipeline.transform = TransformKind::LimitNegExp { ell: 101 };
        assert_ne!(config_fingerprint(&a.pipeline), config_fingerprint(&c.pipeline));
        // Threads are excluded: the embedding is worker-count-invariant.
        let mut d = ritz_serve_cfg(3);
        d.pipeline.threads = 8;
        assert_eq!(config_fingerprint(&a.pipeline), config_fingerprint(&d.pipeline));
    }
}
