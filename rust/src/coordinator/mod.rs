//! L3 coordinator: configuration, the end-to-end SPED pipeline, the
//! parallel walker fleet, and the experiment harnesses that regenerate
//! every figure of the paper.

pub mod experiments;
pub mod pipeline;
pub mod serve;
pub mod stream;
pub mod walkers;
