//! API-compatible stand-in for the PJRT `xla` bindings.
//!
//! The production image ships real PJRT bindings (xla_extension); this
//! offline checkout vendors only the type surface the `sped::runtime`
//! module compiles against, so `cargo build --features xla` type-checks
//! everywhere. Every entry point that would touch PJRT returns an error
//! (or is unreachable because no client can be constructed). To execute
//! AOT artifacts for real, point the `xla` path dependency in
//! `rust/Cargo.toml` at the actual bindings.

use std::fmt;
use std::path::Path;

/// Error type mirroring the real bindings' crate error.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla (stub): {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error("PJRT runtime not linked in this build (vendored stub crate)".to_string())
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side tensor value.
#[derive(Clone, Debug, Default)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module (text interchange).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// A computation ready for compilation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client handle.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}
