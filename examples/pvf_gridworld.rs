//! Proto-value functions for the 3-room MDP (§5.3, Figures 1–3).
//!
//! ```bash
//! cargo run --release --example pvf_gridworld
//! ```
//!
//! Builds the Figure-1 grid world, renders it, computes the bottom-k PVFs
//! through the SPED pipeline (exact −e^{−L} transform) and shows:
//!   * the Fiedler vector's room structure (ASCII heat map),
//!   * convergence acceleration vs the identity transform,
//!   * a downstream RL-style use: least-squares value-function fitting in
//!     the PVF basis (Mahadevan 2005).

use sped::linalg::metrics::subspace_error;
use sped::mdp::{negative_distance_value, proto_value_functions, pvf_value_fit, GridWorld, ThreeRoomSpec};
use sped::pipeline::{Pipeline, PipelineConfig};
use sped::transforms::TransformKind;

fn main() -> anyhow::Result<()> {
    let world = GridWorld::three_rooms(ThreeRoomSpec { s: 1, h: 10 })?;
    println!(
        "3-room MDP: {}×{} cells, {} states, {} transitions\n",
        world.rows,
        world.cols,
        world.num_states(),
        world.graph.num_edges()
    );
    println!("world (Figure 1):\n{}", world.render());

    let k = 8;
    let exact_pvf = proto_value_functions(&world, k)?;
    println!("2nd PVF (Fiedler vector) — separates the outer rooms:");
    println!("{}", world.render_field(&exact_pvf.col(1)));

    // SPED vs identity on the PVF computation.
    //
    // NOTE on the streak: this grid world has an *exactly* 3-fold
    // degenerate eigenvalue (the per-room vertical modes decouple when the
    // door sits on the mode's nodal row), so individual eigenvectors inside
    // that group are not identifiable. We therefore report the
    // degeneracy-aware streak (group-subspace projection).
    let e = sped::linalg::eigh(&world.graph.laplacian())?;
    for transform in [TransformKind::Identity, TransformKind::NegExp] {
        let cfg = PipelineConfig {
            k,
            transform,
            solver: "mu-eg".into(),
            eta: auto_eta(&world.graph, transform),
            steps: 30_000,
            eval_every: 100,
            stop_error: 1e-4,
            do_cluster: false,
            ..Default::default()
        };
        let out = Pipeline::new(cfg).run(&world.graph)?;
        let last = out.history.last().unwrap();
        let err_vs_exact = subspace_error(&exact_pvf, &out.embedding);
        let grouped = sped::linalg::metrics::eigenvector_streak_grouped(
            &exact_pvf,
            &e.values[..k],
            &out.embedding,
            1e-2,
            1e-9,
        );
        println!(
            "[{transform}] steps {} | grouped streak {grouped}/{k} | subspace err {:.2e} | vs exact PVFs {:.2e}",
            last.step, last.subspace_error, err_vs_exact
        );
    }

    // Downstream use: value-function approximation in the PVF basis.
    let goal = world.num_states() - 1;
    let target = negative_distance_value(&world, goal);
    println!("\nvalue-function fitting (negated BFS distance to a corner goal):");
    for k_fit in [2usize, 4, 8, 16, 32] {
        let basis = proto_value_functions(&world, k_fit)?;
        let (_, rmse) = pvf_value_fit(&basis, &target);
        println!("  {k_fit:>3} PVFs → normalized RMSE {rmse:.4}");
    }
    Ok(())
}

fn auto_eta(g: &sped::graph::Graph, t: TransformKind) -> f64 {
    let l = g.laplacian();
    let lam = sped::linalg::funcs::power_lambda_max(&l, 100).unwrap() * 1.01;
    0.5 / (t.lambda_star(lam) - t.scalar_map(0.0)).abs().max(1e-9)
}
