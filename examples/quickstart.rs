//! Quickstart: spectral clustering of a well-clustered graph with SPED.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the paper's §5.4 workload (cliques + short-circuit edges), runs
//! the full pipeline with the limit-approximation transform (the paper's
//! best series), and compares against the identity baseline.

use sped::cluster::adjusted_rand_index;
use sped::graph::gen::{cliques, CliqueSpec};
use sped::pipeline::{Pipeline, PipelineConfig};
use sped::transforms::TransformKind;

fn main() -> anyhow::Result<()> {
    // 1. A well-clustered graph: 4 cliques of 48 nodes, up to 25 random
    //    "short-circuit" edges between each pair (§5.4).
    let gg = cliques(&CliqueSpec { n: 192, k: 4, max_short_circuit: 25, seed: 7 });
    println!(
        "graph: {} nodes, {} edges, 4 ground-truth clusters",
        gg.graph.num_nodes(),
        gg.graph.num_edges(),
    );

    // 2. Run the SPED pipeline: transform −(I − L/251)^251 ≈ −e^{−L}
    //    (eigengap dilation), reverse the spectrum (eq 8), iterate Oja,
    //    k-means the embedding.
    for transform in [TransformKind::Identity, TransformKind::LimitNegExp { ell: 251 }] {
        let cfg = PipelineConfig {
            k: 4,
            transform,
            solver: "oja".into(),
            eta: auto_eta(&gg.graph, transform),
            steps: 30_000,
            eval_every: 50,
            stop_error: 1e-4,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let out = Pipeline::new(cfg).run(&gg.graph)?;
        let last = out.history.last().unwrap();
        let ari = adjusted_rand_index(
            &out.clustering.as_ref().unwrap().assignments,
            &gg.labels,
        );
        println!(
            "\n[{transform}]\n  steps to converge : {}\n  subspace error    : {:.2e}\n  eigenvector streak: {}/4\n  ARI vs truth      : {ari:.3}\n  wall time         : {:.2}s",
            last.step,
            last.subspace_error,
            last.streak,
            t0.elapsed().as_secs_f64()
        );
    }
    println!("\nSPED's transform should converge in ~an order of magnitude fewer steps.");
    Ok(())
}

/// η = 0.5/ρ(M) normalization (see coordinator::experiments).
fn auto_eta(g: &sped::graph::Graph, t: TransformKind) -> f64 {
    let l = g.laplacian();
    let lam = sped::linalg::funcs::power_lambda_max(&l, 100).unwrap() * 1.01;
    0.5 / (t.lambda_star(lam) - t.scalar_map(0.0)).abs().max(1e-9)
}
