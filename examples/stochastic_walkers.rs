//! The stochastic & parallel side of SPED (§4.3): unbiased estimation of
//! Laplacian powers from random walks on the edge-incidence graph, with a
//! leader/worker walker fleet.
//!
//! ```bash
//! cargo run --release --example stochastic_walkers
//! ```
//!
//! Shows:
//!   * Monte-Carlo convergence of the L² estimator (error ~ 1/√walks),
//!   * rejection sampling (the paper's scheme, eqs 13–14) vs importance
//!     weighting (the paper's future-work variance reduction),
//!   * sub-walk harvesting: one walk feeding a whole polynomial p(L)·V,
//!   * a stochastic Oja run driven *only* by walk estimates.

use std::sync::Arc;

use sped::coordinator::walkers::{WalkerPool, WalkerPoolConfig};
use sped::graph::gen::{cliques, CliqueSpec};
use sped::linalg::funcs::matpow;
use sped::solvers::stochastic::StochasticPolyOp;
use sped::solvers::{run_convergence, Oja, RunConfig};
use sped::transforms::{ChebSeries, PolyBasis, SeriesForm};
use sped::walks::{SampleMethod, WalkEstimator};

fn main() -> anyhow::Result<()> {
    let gg = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 3, seed: 3 });
    let g = gg.graph;
    let l = g.laplacian();
    let l2 = matpow(&l, 2);
    println!(
        "graph: {} nodes, {} edges, max degree {} (deg*_inc = {})",
        g.num_nodes(),
        g.num_edges(),
        g.max_degree(),
        2 * g.max_degree() - 1
    );

    // --- estimator convergence, fleet-parallel ---
    println!("\nL² estimation with the walker fleet (4 workers, importance):");
    let pool = WalkerPool::spawn(Arc::new(g.clone()), WalkerPoolConfig::default());
    for walks in [2_000usize, 8_000, 32_000, 128_000] {
        let t0 = std::time::Instant::now();
        let (est, stats) = pool.estimate_power(2, walks, 16, walks as u64);
        let rel = (&est - &l2).max_abs() / l2.max_abs();
        println!(
            "  {walks:>7} walks → rel err {rel:.4}   ({:.0} walks/s)",
            stats.trials as f64 / t0.elapsed().as_secs_f64()
        );
    }
    pool.shutdown();

    // --- rejection vs importance ---
    println!("\nrejection (paper, eqs 13-14) vs importance (future work):");
    for method in [SampleMethod::Rejection, SampleMethod::Importance] {
        let (est, stats) =
            sped::walks::estimate_l_power(&g, 3, 60_000, 4, method, 11);
        let l3 = matpow(&l, 3);
        let rel = (&est - &l3).max_abs() / l3.max_abs();
        println!(
            "  {method:?}: L³ rel err {rel:.4}, acceptance rate {:.3}, weight σ {:.1}",
            stats.acceptance_rate(),
            stats.weight_stats.stddev()
        );
    }

    // --- sub-walk harvesting: polynomial apply ---
    println!("\nsub-walk harvesting: p(L)·V with p(x) = x − 0.1x² + 0.01x³ from ONE walk set:");
    let v = sped::solvers::random_init(g.num_nodes(), 4, 5);
    let coeffs = [0.0, 1.0, -0.1, 0.01];
    let exact = sped::linalg::matmul::matmul(
        &sped::linalg::funcs::poly_horner(&l, &coeffs),
        &v,
    );
    let est = WalkEstimator::new(&g, SampleMethod::Importance);
    let mut rng = sped::util::rng::Rng::new(17);
    for walks in [5_000usize, 40_000] {
        let approx = est.estimate_poly_apply(&coeffs, &v, walks, &mut rng);
        let rel = (&approx - &exact).max_abs() / exact.max_abs();
        println!("  {walks:>6} walks → rel err {rel:.4}");
    }

    // --- fully stochastic solve ---
    println!("\nOja driven purely by walk estimates (no dense matrix ever formed):");
    let e = sped::linalg::eigh(&l)?; // metric oracle only — not on the solve path
    let v_star = e.bottom_k(2);
    // λ* from the CSR-routed estimate: the solve path itself never builds
    // an n×n Laplacian, λ* included.
    let lam_star = StochasticPolyOp::auto_lambda_star(
        &g,
        sped::transforms::TransformKind::Identity,
        100,
        1.05,
        1,
    )?;
    let mut op = StochasticPolyOp::new(
        &g,
        vec![0.0, 1.0],
        lam_star,
        4_000, // walks per step: variance ∝ 1/walks — the knob a fleet scales
        SampleMethod::Importance,
        23,
    );
    let mut solver = Oja { eta: 0.05 / lam_star };
    let cfg = RunConfig { steps: 3000, eval_every: 250, ..Default::default() };
    let hist = run_convergence(&mut solver, &mut op, &v_star, &cfg);
    for p in &hist.points {
        println!("  step {:>5}: subspace err {:.3}, streak {}", p.step, p.subspace_error, p.streak);
    }

    // --- Chebyshev-basis coefficients into the stochastic oracle ---
    // Filters designed in the Chebyshev basis (the stable representation
    // for the deterministic SparsePolyOp path) drop straight into the walk
    // estimator: new_in_basis converts exactly to the monomial form the
    // sub-walk harvester consumes (low degree — the walk-variance regime).
    println!("\nsame filter handed over as Chebyshev coefficients on [0, λ̂_max]:");
    let domain = (0.0, lam_star);
    let cheb = ChebSeries::from_series_form(
        &SeriesForm { shift: 0.0, coeffs: vec![0.0, 1.0] },
        domain.0,
        domain.1,
    );
    let mut op_cheb = StochasticPolyOp::new_in_basis(
        &g,
        PolyBasis::Chebyshev,
        cheb.coeffs,
        domain,
        lam_star,
        4_000,
        SampleMethod::Importance,
        23,
    );
    let hist_cheb = run_convergence(&mut Oja { eta: 0.05 / lam_star }, &mut op_cheb, &v_star, &cfg);
    let (a, b) = (hist.last().unwrap(), hist_cheb.last().unwrap());
    println!(
        "  monomial err {:.3} vs chebyshev-handed err {:.3} (identical walks, exact conversion)",
        a.subspace_error, b.subspace_error
    );
    Ok(())
}
