//! END-TO-END VALIDATION DRIVER — exercises every layer of the stack on a
//! real workload and reports the paper's headline metric.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_sped
//! ```
//!
//! Pipeline per run (all through the **XLA backend**; Python never runs):
//!   1. rust builds the §5.4 clique workload and its Laplacian;
//!   2. the series transform is materialized by AOT XLA artifacts
//!      (`matpow` square-and-multiply — L1 Pallas matmul kernel inside);
//!   3. the spectrum is reversed (eq 8) and padded to the artifact size;
//!   4. Oja iterates in T=25-step XLA chunks (`oja_chunk` — L1 fused
//!      kernel + in-graph §5.2 metrics);
//!   5. rust k-means the embedding and scores ARI vs ground truth.
//!
//! The run compares identity vs the limit transform end-to-end and prints
//! steps-to-convergence, wall-times per stage and solver-step throughput.
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use sped::cluster::adjusted_rand_index;
use sped::graph::gen::{cliques, CliqueSpec};
use sped::pipeline::{Backend, Pipeline, PipelineConfig};
use sped::transforms::TransformKind;

fn main() -> anyhow::Result<()> {
    let artifacts_dir = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    if !std::path::Path::new(&artifacts_dir).join("manifest.cfg").exists() {
        anyhow::bail!(
            "artifacts not found in {artifacts_dir:?} — run `make artifacts` first \
             (the e2e driver exercises the AOT XLA path)"
        );
    }

    // A real small workload: 3 communities, 360 nodes, ~7k edges.
    let gg = cliques(&CliqueSpec { n: 360, k: 3, max_short_circuit: 20, seed: 2024 });
    println!(
        "workload: {} nodes, {} edges, 3 ground-truth communities",
        gg.graph.num_nodes(),
        gg.graph.num_edges()
    );
    println!("artifacts: {artifacts_dir}/ (XLA backend, padded to n=512)\n");

    let mut rows = Vec::new();
    for (name, transform) in [
        ("identity (baseline)", TransformKind::Identity),
        ("limit −(I−L/251)^251 (SPED)", TransformKind::LimitNegExp { ell: 251 }),
    ] {
        let eta = {
            let l = gg.graph.laplacian();
            let lam = sped::linalg::funcs::power_lambda_max(&l, 100).unwrap() * 1.01;
            0.5 / (transform.lambda_star(lam) - transform.scalar_map(0.0)).abs()
        };
        let cfg = PipelineConfig {
            k: 3,
            transform,
            solver: "oja".into(),
            eta,
            steps: 30_000,
            eval_every: 25,
            stop_error: 1e-4,
            backend: Backend::Xla { artifacts_dir: artifacts_dir.clone() },
            seed: 99,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let out = Pipeline::new(cfg).run(&gg.graph)?;
        let wall = t0.elapsed().as_secs_f64();
        let last = out.history.last().unwrap();
        let ari = adjusted_rand_index(
            &out.clustering.as_ref().unwrap().assignments,
            &gg.labels,
        );
        let steps_per_s = last.step as f64 / out.timings.solve.max(1e-9);
        println!("── {name} ──");
        println!("  solver steps         : {}", last.step);
        println!("  subspace error       : {:.2e}", last.subspace_error);
        println!("  eigenvector streak   : {}/3", last.streak);
        println!("  ARI vs ground truth  : {ari:.3}");
        println!(
            "  stage times          : truth {:.2}s | transform(XLA) {:.2}s | solve(XLA) {:.2}s | kmeans {:.2}s",
            out.timings.ground_truth,
            out.timings.transform_build,
            out.timings.solve,
            out.timings.cluster
        );
        println!("  solver throughput    : {steps_per_s:.0} XLA steps/s");
        println!("  total wall           : {wall:.2}s\n");
        rows.push((name, last.step, ari));
    }
    let speedup = rows[0].1 as f64 / rows[1].1.max(1) as f64;
    println!("steps-to-convergence speedup (identity / SPED): {speedup:.1}×");
    println!("(paper's claim: about an order of magnitude for the series transform)");
    anyhow::ensure!(rows[1].2 > 0.9, "SPED run failed to recover the communities");
    Ok(())
}
