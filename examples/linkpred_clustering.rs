//! Clustering a probabilistic graph completed by link prediction
//! (Appendix A.1 / Figure 5).
//!
//! ```bash
//! cargo run --release --example linkpred_clustering
//! ```
//!
//! Drops 20% of the edges of a well-clustered graph, predicts the missing
//! edges with common neighbors, normalizes scores into probabilistic
//! weights, and spectral-clusters the resulting *weighted* Laplacian
//! `XᵀWX` through SPED — demonstrating that eigengap dilation carries over
//! to weighted graphs (it only touches the spectrum).

use sped::cluster::adjusted_rand_index;
use sped::graph::gen::{cliques, CliqueSpec};
use sped::linkpred::{complete_graph, drop_edges, normalize_scores, score_pairs};
use sped::pipeline::{Pipeline, PipelineConfig};
use sped::transforms::TransformKind;

fn main() -> anyhow::Result<()> {
    let gg = cliques(&CliqueSpec { n: 180, k: 3, max_short_circuit: 10, seed: 42 });
    println!(
        "original: {} nodes, {} edges, 3 clusters",
        gg.graph.num_nodes(),
        gg.graph.num_edges()
    );

    let dropped = drop_edges(&gg.graph, 0.2, 7)?;
    println!("dropped {} edges (p = 0.2)", dropped.removed.len());

    // Show the link predictor at work.
    let scores = score_pairs(&dropped.graph, &dropped.removed);
    let probs = normalize_scores(&scores);
    let hits = probs.iter().filter(|&&p| p > 0.0).count();
    println!(
        "common-neighbors assigned positive probability to {hits}/{} removed edges",
        dropped.removed.len()
    );

    let completed = complete_graph(&dropped)?;
    println!(
        "completed graph: {} edges ({} surviving + {} predicted, weighted)",
        completed.num_edges(),
        dropped.graph.num_edges(),
        completed.num_edges() - dropped.graph.num_edges()
    );

    for (label, graph) in [("dropped-only", &dropped.graph), ("completed", &completed)] {
        let transform = TransformKind::LimitNegExp { ell: 251 };
        let cfg = PipelineConfig {
            k: 3,
            transform,
            solver: "oja".into(),
            eta: auto_eta(graph, transform),
            steps: 20_000,
            eval_every: 50,
            stop_error: 1e-4,
            ..Default::default()
        };
        let out = Pipeline::new(cfg).run(graph)?;
        let ari = adjusted_rand_index(
            &out.clustering.as_ref().unwrap().assignments,
            &gg.labels,
        );
        let last = out.history.last().unwrap();
        println!(
            "[{label:>12}] steps {} | streak {}/3 | ARI vs original truth {ari:.3}",
            last.step, last.streak
        );
    }
    Ok(())
}

fn auto_eta(g: &sped::graph::Graph, t: TransformKind) -> f64 {
    let l = g.laplacian();
    let lam = sped::linalg::funcs::power_lambda_max(&l, 100).unwrap() * 1.01;
    0.5 / (t.lambda_star(lam) - t.scalar_map(0.0)).abs().max(1e-9)
}
