"""L2 — the JAX compute graphs lowered to AOT artifacts.

Everything the rust coordinator executes at runtime is defined here as a
pure jax function over fixed shapes, calling the L1 Pallas kernels:

* :func:`oja_chunk` / :func:`eg_chunk` — ``T`` solver steps per call with
  the paper's §5.2 metrics (subspace error, per-vector alignment) computed
  in-graph against the supplied ground truth.
* :func:`poly_build` — Horner evaluation of a series transform
  ``Σ c_i (L − s·I)^i`` (runtime coefficients, static degree).
* :func:`matpow_bits` — ``B^p`` by square-and-multiply with a runtime bit
  mask (the limit transform ``−(I − L/ℓ)^ℓ`` for any odd ℓ < 2^bits).
* :func:`matvec` — plain ``M @ V`` (cross-validation oracle + XlaDenseOp).
* :func:`stoch_chunk` — walk-batch stochastic apply (§4.3) feeding one
  solver step.

Python runs only at ``make artifacts`` time; see aot.py.
"""

import jax
import jax.numpy as jnp

from compile.kernels import poly_horner, solver_step, stoch_apply


# ---------------------------------------------------------------------------
# metrics (paper §5.2), computed in-graph
# ---------------------------------------------------------------------------

def subspace_error(v_star, v):
    """δ = 1 − tr(U* P)/k for orthonormal v_star; v is orthonormalized by
    construction in both solvers (QR / per-column normalization makes this
    an adequate proxy at f32 tolerance)."""
    k = v.shape[1]
    m = v_star.T @ v
    return 1.0 - jnp.sum(m * m) / k


def alignments(v_star, v):
    """Per-vector |cos| alignment (columns assumed ~unit norm)."""
    num = jnp.abs(jnp.sum(v_star * v, axis=0))
    den = jnp.linalg.norm(v_star, axis=0) * jnp.linalg.norm(v, axis=0) + 1e-30
    return num / den


# ---------------------------------------------------------------------------
# solver steps
# ---------------------------------------------------------------------------

def _orthonormalize(v):
    """Modified Gram–Schmidt over the (static, small) column count.

    Pure dots/axpys — deliberately NOT `jnp.linalg.qr`, which lowers to a
    LAPACK typed-FFI custom-call that the runtime's XLA (0.5.1) cannot
    load. k ≤ 8, so the unrolled loop is cheap and fusion-friendly.
    """
    k = v.shape[1]
    cols = [v[:, i] for i in range(k)]
    out = []
    for i in range(k):
        c = cols[i]
        for q in out:
            c = c - jnp.dot(q, c) * q
        # Second projection pass for f32 robustness (MGS2).
        for q in out:
            c = c - jnp.dot(q, c) * q
        c = c / (jnp.linalg.norm(c) + 1e-30)
        out.append(c)
    return jnp.stack(out, axis=1)


def oja_step(m, v, eta):
    """One Oja step: orth(V + η·MV); matmul through the fused L1 kernel."""
    g = solver_step.oja_update(m, v, eta)
    return _orthonormalize(g)


def eg_step(m, v, eta):
    """One µ-EigenGame (unloaded) step.

    grad_i = (MV)_i − Σ_{j<i} (v_jᵀ M v_i) v_j, Riemannian-projected and
    retracted to the sphere per column.
    """
    g = solver_step.matvec(m, v)
    a = v.T @ g  # (k, k); a[j, i] = v_jᵀ M v_i
    k = v.shape[1]
    mask = jnp.triu(jnp.ones((k, k), v.dtype), 1)  # strictly upper: j < i
    grad = g - v @ (a * mask)
    vg = jnp.sum(v * grad, axis=0)  # per-column ⟨v_i, grad_i⟩
    new_v = v + eta * (grad - v * vg[None, :])
    norms = jnp.linalg.norm(new_v, axis=0) + 1e-30
    return new_v / norms[None, :]


def _chunk(step_fn, t):
    """T steps of `step_fn` with per-step metrics, as a lax.scan."""

    def chunk(m, v, v_star, eta):
        def body(v, _):
            v2 = step_fn(m, v, eta)
            return v2, (subspace_error(v_star, v2), alignments(v_star, v2))

        v_final, (errs, aligns) = jax.lax.scan(body, v, None, length=t)
        return v_final, errs, aligns

    return chunk


def oja_chunk(t):
    """T Oja steps + metrics: (M, V, V*, η) → (V', errs(T,), aligns(T,k))."""
    return _chunk(oja_step, t)


def eg_chunk(t):
    """T µ-EG steps + metrics."""
    return _chunk(eg_step, t)


# ---------------------------------------------------------------------------
# transform builders
# ---------------------------------------------------------------------------

def poly_build(l, coeffs, shift):
    """p(L) = Σ coeffs[i] (L − shift·I)^i via the fused Horner kernel."""
    n = l.shape[0]
    b = l - shift * jnp.eye(n, dtype=l.dtype)
    return poly_horner.horner(b, coeffs)


def matpow_bits(b, bits):
    """B^p with p given as a 0/1 float mask (LSB first), square-and-multiply
    over the L1 matmul kernel inside a scan: `bits` static length."""
    n = b.shape[0]

    def body(carry, bit):
        acc, base = carry
        mult = poly_horner.matmul(acc, base)
        acc = jnp.where(bit > 0.5, mult, acc)
        base = poly_horner.matmul(base, base)
        return (acc, base), ()

    (acc, _), _ = jax.lax.scan(body, (jnp.eye(n, dtype=b.dtype), b), bits)
    return acc


def matvec(m, v):
    """M @ V (the XlaDenseOp oracle)."""
    return solver_step.matvec(m, v)


# ---------------------------------------------------------------------------
# stochastic SPED (§4.3)
# ---------------------------------------------------------------------------

def stoch_chunk(v, idx, w, lam_star, eta):
    """One stochastic solver step from a walk batch.

    M̂V = λ*·V − stoch_apply(V, idx, w); then an Oja update + QR. The rust
    walker fleet supplies (idx, w) with all α/p/num_walks scaling folded
    into w.
    """
    est = stoch_apply.stoch_apply(v, idx, w)
    g = lam_star * v - est
    return _orthonormalize(v + eta * g)
