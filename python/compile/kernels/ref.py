"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every kernel in this package must match its `*_ref` twin to float32
tolerance; pytest (with hypothesis shape/value sweeps) enforces this at
build time before any artifact is emitted.
"""

import jax.numpy as jnp


def matmul_add_diag_ref(a, b, c):
    """O = A @ B + c·I (one Horner term; rectangular shapes get the
    leading-diagonal generalization)."""
    out = a @ b
    return out + c * jnp.eye(out.shape[0], out.shape[1], dtype=out.dtype)


def horner_ref(b, coeffs):
    """p(B) = Σ coeffs[i] · B^i by Horner (coeffs ascending)."""
    n = b.shape[0]
    r = coeffs[-1] * jnp.eye(n, dtype=b.dtype)
    for c in coeffs[-2::-1]:
        r = r @ b + c * jnp.eye(n, dtype=b.dtype)
    return r


def matpow_bits_ref(b, bits):
    """B^p where p = Σ bits[i]·2^i (bits float 0/1, LSB first)."""
    n = b.shape[0]
    acc = jnp.eye(n, dtype=b.dtype)
    base = b
    for bit in bits:
        acc = jnp.where(bit > 0.5, acc @ base, acc)
        base = base @ base
    return acc


def oja_update_ref(m, v, eta):
    """Fused Oja pre-orthonormalization update G = V + η·(M @ V)."""
    return v + eta * (m @ v)


def stoch_apply_ref(v, idx, w):
    """Walk-batch apply (§4.3, eq 12).

    idx: (B, 4) int32 rows [e1_u, e1_v, el_u, el_v]; w: (B,) chain weights
    (already scaled by α/p/num_walks). Output: Σ_b w_b · x_{e1,b} (x_{el,b}ᵀ V),
    an (n, k) matrix.
    """
    d = (v[idx[:, 2]] - v[idx[:, 3]]) * w[:, None]  # (B, k)
    out = jnp.zeros_like(v)
    out = out.at[idx[:, 0]].add(d)
    out = out.at[idx[:, 1]].add(-d)
    return out


def gather_diff_ref(v, idx, w):
    """Just the gather-diff-scale stage (the Pallas part of stoch_apply)."""
    return (v[idx[:, 2]] - v[idx[:, 3]]) * w[:, None]
