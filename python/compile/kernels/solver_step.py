"""Fused Oja pre-orthonormalization update: ``G = V + η·(M @ V)``.

The matmul dominates (n×n×k); fusing the scale-and-add into the reduction
epilogue saves one HBM pass over the (n, k) panel. Grid (i, kk): block rows
of M times the (resident) V panel — V is only n×8×4 B ≤ 64 KiB for the
largest artifact, so it sits whole in VMEM (BlockSpec maps the full panel
to every grid step), the TPU-idiomatic layout for skinny right-hand sides.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128


def _oja_kernel_fused(m_ref, vk_ref, vrow_ref, eta_ref, o_ref, *, nk: int):
    """m_ref: (bm, bk) block of M; vk_ref: (bk, k) slice of V for the
    reduction; vrow_ref: (bm, k) rows of V matching the output block;
    eta_ref: (1,)."""
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        m_ref[...], vk_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    # Fused epilogue G = V + η·acc, arithmetic-masked to the last reduction
    # step (nested pl.when does not lower in interpret mode).
    last = (kk == nk - 1).astype(o_ref.dtype)
    o_ref[...] = (1.0 - last) * o_ref[...] + last * (
        vrow_ref[...] + eta_ref[0] * o_ref[...]
    )


@functools.partial(jax.jit, static_argnames=())
def oja_update(m, v, eta):
    """``V + η·(M @ V)`` via the fused Pallas kernel.

    m: (n, n); v: (n, k); eta: traced scalar. Returns (n, k) float32.
    """
    m = m.astype(jnp.float32)
    v = v.astype(jnp.float32)
    n, n2 = m.shape
    assert n == n2 and v.shape[0] == n
    k = v.shape[1]
    bm = min(BLOCK, n)
    bk = min(BLOCK, n)
    npad = -(-n // bm) * bm
    if npad != n:
        m = jnp.pad(m, ((0, npad - n), (0, npad - n)))
        v = jnp.pad(v, ((0, npad - n), (0, 0)))
    nk = npad // bk
    eta_arr = jnp.reshape(jnp.asarray(eta, jnp.float32), (1,))
    out = pl.pallas_call(
        functools.partial(_oja_kernel_fused, nk=nk),
        grid=(npad // bm, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, kk: (i, kk)),
            pl.BlockSpec((bk, k), lambda i, kk: (kk, 0)),
            pl.BlockSpec((bm, k), lambda i, kk: (i, 0)),
            pl.BlockSpec((1,), lambda i, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i, kk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, k), jnp.float32),
        interpret=True,
    )(m, v, v, eta_arr)
    return out[:n]


def matvec(m, v):
    """Plain ``M @ V`` through the fused kernel (η = 1 on a zero base):
    computed as ``0·V + 1·(M@V)`` by passing a zero row panel."""
    zero_rows = jnp.zeros_like(v)
    m = m.astype(jnp.float32)
    v = v.astype(jnp.float32)
    n = m.shape[0]
    k = v.shape[1]
    bm = min(BLOCK, n)
    bk = min(BLOCK, n)
    npad = -(-n // bm) * bm
    if npad != n:
        m = jnp.pad(m, ((0, npad - n), (0, npad - n)))
        v = jnp.pad(v, ((0, npad - n), (0, 0)))
        zero_rows = jnp.pad(zero_rows, ((0, npad - n), (0, 0)))
    nk = npad // bk
    one = jnp.ones((1,), jnp.float32)
    out = pl.pallas_call(
        functools.partial(_oja_kernel_fused, nk=nk),
        grid=(npad // bm, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, kk: (i, kk)),
            pl.BlockSpec((bk, k), lambda i, kk: (kk, 0)),
            pl.BlockSpec((bm, k), lambda i, kk: (i, 0)),
            pl.BlockSpec((1,), lambda i, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i, kk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, k), jnp.float32),
        interpret=True,
    )(m, v, zero_rows, one)
    return out[:n]
