"""Fused blocked matmul + diagonal epilogue: one Horner term of a series
transform (§4.2 of the paper): ``O = A @ B + c·I``.

TPU design (see DESIGN.md §Hardware-Adaptation): 128×128 MXU-aligned blocks
over a 3-d grid ``(i, j, kk)``; the k-reduction accumulates into the output
block (revisited across the sequentially-iterated minor grid axis), and the
``+c·δ_ij`` diagonal add is fused into the epilogue of the last reduction
step — one HBM round-trip per Horner term instead of two. VMEM working set:
3 blocks × 128² × 4 B = 192 KiB ≪ 16 MiB.

Runs ``interpret=True`` on CPU for correctness; the grid/BlockSpec structure
is exactly what Mosaic would compile for a real TPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned tile edge.
BLOCK = 128


def _matmul_diag_kernel(a_ref, b_ref, c_ref, o_ref, *, nk: int):
    """Grid (i, j, kk): O[i,j] += A[i,kk] @ B[kk,j]; diag epilogue at kk end.

    The epilogue is arithmetic-masked rather than `pl.when`-guarded:
    nested `pl.when` closures fail to lower in interpret mode, and on TPU
    a predicated VPU add is as cheap as a branch anyway.
    """
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    i = pl.program_id(0)
    j = pl.program_id(1)
    bm, bn = o_ref.shape
    diag_mask = ((kk == nk - 1) & (i == j)).astype(o_ref.dtype)
    o_ref[...] += (
        jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32).astype(
            o_ref.dtype
        )
        + diag_mask * c_ref[0] * jnp.eye(bm, bn, dtype=o_ref.dtype)
    )


def _block_sizes(m, k, n):
    """Tile edges: MXU blocks when the problem is big enough, the whole
    dimension otherwise (tests use small n)."""
    return min(BLOCK, m), min(BLOCK, k), min(BLOCK, n)


def _pad_to(x, rows, cols):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(jax.jit, static_argnames=())
def matmul_add_diag(a, b, c):
    """``A @ B + c·I`` via the Pallas kernel (padding handled here).

    a: (m, k); b: (k, n); c: scalar (traced). Returns (m, n) float32.
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"shape mismatch {a.shape} @ {b.shape}"
    bm, bk, bn = _block_sizes(m, k, n)
    mp = -(-m // bm) * bm
    kp = -(-k // bk) * bk
    np_ = -(-n // bn) * bn
    a_p = _pad_to(a, mp, kp)
    b_p = _pad_to(b, kp, np_)
    nk = kp // bk
    c_arr = jnp.reshape(jnp.asarray(c, jnp.float32), (1,))
    out = pl.pallas_call(
        functools.partial(_matmul_diag_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p, c_arr)
    return out[:m, :n]


def matmul(a, b):
    """Plain blocked matmul through the same kernel (c = 0)."""
    return matmul_add_diag(a, b, 0.0)


def horner(b, coeffs):
    """``p(B) = Σ coeffs[i] B^i`` by Horner over the fused kernel.

    ``coeffs`` is a *traced* 1-d array (ascending degree, static length D):
    R = c_{D-1}·I; R = R@B + c_i·I for i = D-2 … 0. Exactly D−1 kernel
    launches; lowered as a ``lax.scan`` so the HLO stays compact for any D.
    """
    n = b.shape[0]
    d = coeffs.shape[0]
    r0 = coeffs[d - 1] * jnp.eye(n, dtype=jnp.float32)

    def body(r, c):
        return matmul_add_diag(r, b, c), ()

    # Scan over coefficients from degree D-2 down to 0.
    cs = coeffs[: d - 1][::-1]
    r, _ = jax.lax.scan(body, r0, cs)
    return r
