"""L1 — Pallas kernels for SPED's compute hot spots.

Three kernels cover the paper's inner loops:

* :mod:`poly_horner` — fused blocked matmul + diagonal epilogue
  ``O = A @ B + c * I`` — one Horner term of the series transform (§4.2).
* :mod:`stoch_apply` — the stochastic walk-batch apply of §4.3:
  gather walk-endpoint rows of ``V``, scale by the chain weights.
* :mod:`solver_step` — fused Oja pre-orthonormalization update
  ``G = V + eta * (M @ V)``.

All kernels run ``interpret=True`` (the CPU PJRT plugin cannot execute
Mosaic custom-calls); BlockSpecs are shaped for the TPU MXU/VMEM as
documented in DESIGN.md §Hardware-Adaptation. ``ref.py`` holds the pure-jnp
oracles the pytest suite checks against.
"""

from . import poly_horner, ref, solver_step, stoch_apply  # noqa: F401
