"""Stochastic walk-batch apply (§4.3, eq 12): the gather-diff-scale stage.

Each random walk contributes ``w_b · x_{e1,b} (x_{el,b}ᵀ V)``. The inner
product with the ±1 two-hot edge vector is a two-row gather and subtract:
``d_b = w_b · (V[el_u,b] − V[el_v,b])``. This kernel computes the (B, k)
matrix ``d`` blocked over the batch; the scatter back onto rows ``e1_u/e1_v``
is left to XLA (`.at[].add`, which lowers to an efficient sorted scatter).

TPU shape: V (n ≤ 2048, k = 8 → ≤ 64 KiB) is VMEM-resident and mapped whole
to every batch block (BlockSpec constant index map); the batch dimension is
tiled at 256 walks per block. Gathers hit VMEM, not HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BATCH_BLOCK = 256


def _gather_diff_kernel(v_ref, idx_ref, w_ref, o_ref):
    v = v_ref[...]
    idx = idx_ref[...]
    w = w_ref[...]
    d = (v[idx[:, 2]] - v[idx[:, 3]]) * w[:, None]
    o_ref[...] = d.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def gather_diff(v, idx, w):
    """(B, k) weighted endpoint differences ``w_b (V[el_u] − V[el_v])``.

    v: (n, k) f32; idx: (B, 4) int32 [e1_u, e1_v, el_u, el_v]; w: (B,) f32.
    """
    v = v.astype(jnp.float32)
    w = w.astype(jnp.float32)
    b, four = idx.shape
    assert four == 4
    n, k = v.shape
    bb = min(BATCH_BLOCK, b)
    bpad = -(-b // bb) * bb
    if bpad != b:
        # Padded walks point at row 0 with weight 0 → zero contribution.
        idx = jnp.pad(idx, ((0, bpad - b), (0, 0)))
        w = jnp.pad(w, (0, bpad - b))
    out = pl.pallas_call(
        _gather_diff_kernel,
        grid=(bpad // bb,),
        in_specs=[
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((bb, 4), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bpad, k), jnp.float32),
        interpret=True,
    )(v, idx, w)
    return out[:b]


def stoch_apply(v, idx, w):
    """Full §4.3 estimator application: Σ_b w_b x_{e1,b} (x_{el,b}ᵀ V).

    Pallas gather-diff + XLA scatter-add. Returns (n, k).
    """
    d = gather_diff(v, idx, w)
    out = jnp.zeros_like(v, dtype=jnp.float32)
    out = out.at[idx[:, 0]].add(d)
    out = out.at[idx[:, 1]].add(-d)
    return out
