"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and values; assert_allclose at f32 tolerance.
This is the gate before any artifact is emitted.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import poly_horner, ref, solver_step, stoch_apply

RTOL = 1e-5
ATOL = 1e-5


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# poly_horner.matmul_add_diag
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    c=st.floats(-3, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_add_diag_matches_ref(m, k, n, c, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    got = poly_horner.matmul_add_diag(a, b, c)
    want = ref.matmul_add_diag_ref(jnp.asarray(a), jnp.asarray(b), c)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_matmul_add_diag_crosses_block_boundary():
    # > BLOCK in every dimension exercises the 3-d grid + reduction.
    rng = np.random.default_rng(0)
    a, b = rand(rng, 130, 200), rand(rng, 200, 131)
    got = poly_horner.matmul_add_diag(a, b, 0.5)
    want = ref.matmul_add_diag_ref(jnp.asarray(a), jnp.asarray(b), 0.5)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_identity():
    rng = np.random.default_rng(1)
    a = rand(rng, 40, 40)
    got = poly_horner.matmul(a, np.eye(40, dtype=np.float32))
    np.testing.assert_allclose(got, a, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# poly_horner.horner
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(2, 40),
    deg=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_horner_matches_ref(n, deg, seed):
    rng = np.random.default_rng(seed)
    b = (rand(rng, n, n) * 0.3).astype(np.float32)
    coeffs = rand(rng, deg + 1)
    got = poly_horner.horner(jnp.asarray(b), jnp.asarray(coeffs))
    want = ref.horner_ref(jnp.asarray(b), [float(c) for c in coeffs])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_horner_taylor_negexp_vs_scalar():
    # The actual SPED use: Taylor −e^{−x} coefficients, diagonal matrix →
    # entries must match the scalar series.
    ell = 20
    coeffs = []
    fact = 1.0
    for i in range(ell + 1):
        if i:
            fact *= i
        coeffs.append((-1.0 if i % 2 == 0 else 1.0) / fact)
    d = jnp.diag(jnp.asarray([0.0, 0.5, 1.0, 1.9], jnp.float32))
    got = poly_horner.horner(d, jnp.asarray(coeffs, jnp.float32))
    want = -np.exp(-np.asarray([0.0, 0.5, 1.0, 1.9]))
    np.testing.assert_allclose(np.diagonal(got), want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# solver_step
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 80),
    k=st.integers(1, 8),
    eta=st.floats(0.001, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_oja_update_matches_ref(n, k, eta, seed):
    rng = np.random.default_rng(seed)
    m, v = rand(rng, n, n), rand(rng, n, k)
    got = solver_step.oja_update(m, v, eta)
    want = ref.oja_update_ref(jnp.asarray(m), jnp.asarray(v), eta)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matvec_matches_numpy():
    rng = np.random.default_rng(3)
    m, v = rand(rng, 150, 150), rand(rng, 150, 8)
    got = solver_step.matvec(m, v)
    np.testing.assert_allclose(got, m @ v, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# stoch_apply
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(4, 60),
    k=st.integers(1, 8),
    batch=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_stoch_apply_matches_ref(n, k, batch, seed):
    rng = np.random.default_rng(seed)
    v = rand(rng, n, k)
    idx = rng.integers(0, n, size=(batch, 4)).astype(np.int32)
    w = rand(rng, batch)
    got = stoch_apply.stoch_apply(jnp.asarray(v), jnp.asarray(idx), jnp.asarray(w))
    want = ref.stoch_apply_ref(jnp.asarray(v), jnp.asarray(idx), jnp.asarray(w))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gather_diff_zero_weights_vanish():
    rng = np.random.default_rng(5)
    v = rand(rng, 10, 3)
    idx = rng.integers(0, 10, size=(7, 4)).astype(np.int32)
    w = np.zeros(7, np.float32)
    got = stoch_apply.gather_diff(jnp.asarray(v), jnp.asarray(idx), jnp.asarray(w))
    assert np.abs(np.asarray(got)).max() == 0.0


def test_stoch_apply_single_walk_outer_product():
    # One walk e1=(0,1), el=(2,3), w=2 → 2·x_{01} x_{23}ᵀ V.
    v = jnp.asarray(np.arange(12, dtype=np.float32).reshape(4, 3))
    idx = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    w = jnp.asarray([2.0], jnp.float32)
    got = np.asarray(stoch_apply.stoch_apply(v, idx, w))
    d = 2.0 * (np.asarray(v)[2] - np.asarray(v)[3])
    want = np.zeros((4, 3), np.float32)
    want[0] = d
    want[1] = -d
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
