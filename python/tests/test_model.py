"""L2 correctness: solver chunks, transform builders, in-graph metrics."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _sym(rng, n):
    a = rng.standard_normal((n, n)).astype(np.float32)
    return (a + a.T) / 2


def _orth(rng, n, k):
    q, _ = np.linalg.qr(rng.standard_normal((n, k)))
    return q.astype(np.float32)


def _reversed_psd(rng, n, spread=1.0):
    """A PSD matrix whose top eigenvectors are well separated."""
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    vals = np.linspace(1.0, 0.0, n) ** 2 * spread
    return (q * vals) @ q.T, q[:, :], vals


def test_subspace_error_in_graph_matches_definition():
    rng = np.random.default_rng(0)
    v_star = _orth(rng, 20, 4)
    # Same subspace → 0; orthogonal subspace → 1.
    err_same = float(model.subspace_error(jnp.asarray(v_star), jnp.asarray(v_star)))
    assert err_same < 1e-6
    v2 = _orth(rng, 20, 4)
    # Make v2 orthogonal to v_star's span.
    v2 = v2 - v_star @ (v_star.T @ v2)
    v2, _ = np.linalg.qr(v2)
    err_orth = float(model.subspace_error(jnp.asarray(v_star), jnp.asarray(v2.astype(np.float32))))
    assert err_orth > 0.999


def test_alignments_sign_invariant():
    rng = np.random.default_rng(1)
    v_star = _orth(rng, 15, 3)
    v = v_star.copy()
    v[:, 1] *= -1
    a = np.asarray(model.alignments(jnp.asarray(v_star), jnp.asarray(v)))
    np.testing.assert_allclose(a, 1.0, atol=1e-6)


def test_oja_chunk_converges_to_top_eigenvectors():
    rng = np.random.default_rng(2)
    n, k, t = 30, 3, 25
    m, q, vals = _reversed_psd(rng, n)
    v_star = q[:, :k]  # top eigenvectors (vals descending)
    chunk = model.oja_chunk(t)
    v = _orth(rng, n, k)
    errs = []
    for _ in range(12):
        v, e, a = chunk(jnp.asarray(m), jnp.asarray(v), jnp.asarray(v_star), 0.5)
        errs.append(float(e[-1]))
    assert errs[-1] < 1e-3, errs
    assert errs[-1] <= errs[0]


def test_eg_chunk_orders_eigenvectors():
    rng = np.random.default_rng(3)
    n, k, t = 24, 3, 25
    m, q, vals = _reversed_psd(rng, n, spread=2.0)
    v_star = q[:, :k]
    chunk = model.eg_chunk(t)
    v = _orth(rng, n, k)
    aligns = None
    for _ in range(40):
        v, e, a = chunk(jnp.asarray(m), jnp.asarray(v), jnp.asarray(v_star), 0.3)
        aligns = np.asarray(a[-1])
    # Every individual eigenvector recovered (streak k) — µ-EG's promise.
    assert (aligns > 0.98).all(), aligns


def test_chunk_metrics_shapes():
    rng = np.random.default_rng(4)
    n, k, t = 12, 2, 7
    chunk = model.oja_chunk(t)
    m = _sym(rng, n)
    v = _orth(rng, n, k)
    v2, errs, aligns = chunk(jnp.asarray(m), jnp.asarray(v), jnp.asarray(v), 0.1)
    assert v2.shape == (n, k)
    assert errs.shape == (t,)
    assert aligns.shape == (t, k)


def test_poly_build_matches_horner_ref():
    rng = np.random.default_rng(5)
    n = 16
    l = _sym(rng, n) * 0.2
    coeffs = np.asarray([0.5, -1.0, 0.25, 0.1], np.float32)
    shift = 0.3
    got = model.poly_build(jnp.asarray(l), jnp.asarray(coeffs), shift)
    b = jnp.asarray(l) - shift * jnp.eye(n, dtype=jnp.float32)
    want = ref.horner_ref(b, [float(c) for c in coeffs])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_poly_build_zero_padded_coeffs_harmless():
    # The rust side zero-pads coefficients to the artifact degree.
    rng = np.random.default_rng(6)
    n = 10
    l = _sym(rng, n) * 0.2
    c_short = np.asarray([0.5, -1.0, 0.25], np.float32)
    c_padded = np.concatenate([c_short, np.zeros(13, np.float32)])
    a = model.poly_build(jnp.asarray(l), jnp.asarray(c_short), 0.0)
    b = model.poly_build(jnp.asarray(l), jnp.asarray(c_padded), 0.0)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("p", [1, 2, 3, 7, 251])
def test_matpow_bits_matches_numpy(p):
    rng = np.random.default_rng(7)
    n = 12
    b = _sym(rng, n) * (0.8 / n)  # spectral radius < 1 keeps powers tame
    bits = np.asarray([(p >> i) & 1 for i in range(9)], np.float32)
    got = model.matpow_bits(jnp.asarray(b), jnp.asarray(bits))
    want = np.linalg.matrix_power(b.astype(np.float64), p)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=1e-5)


def test_limit_negexp_through_matpow():
    # −(I − L/ℓ)^ℓ ≈ −e^{−L} on a small Laplacian-like matrix.
    ell = 251
    rng = np.random.default_rng(8)
    x = rng.standard_normal((20, 6)).astype(np.float32)
    l = (x @ x.T) / 10
    b = np.eye(20, dtype=np.float32) - l / ell
    bits = np.asarray([(ell >> i) & 1 for i in range(9)], np.float32)
    got = -np.asarray(model.matpow_bits(jnp.asarray(b), jnp.asarray(bits)))
    evals, evecs = np.linalg.eigh(l.astype(np.float64))
    want = -(evecs * np.exp(-evals)) @ evecs.T
    np.testing.assert_allclose(got, want, rtol=0.05, atol=5e-3)


def test_matvec():
    rng = np.random.default_rng(9)
    m = _sym(rng, 40)
    v = rng.standard_normal((40, 8)).astype(np.float32)
    got = model.matvec(jnp.asarray(m), jnp.asarray(v))
    np.testing.assert_allclose(got, m @ v, rtol=1e-4, atol=1e-4)


def test_stoch_chunk_step_is_orthonormal():
    rng = np.random.default_rng(10)
    n, k, batch = 16, 3, 50
    v = _orth(rng, n, k)
    idx = rng.integers(0, n, size=(batch, 4)).astype(np.int32)
    w = rng.standard_normal(batch).astype(np.float32) * 0.01
    v2 = model.stoch_chunk(
        jnp.asarray(v), jnp.asarray(idx), jnp.asarray(w), 2.0, 0.05
    )
    gram = np.asarray(v2).T @ np.asarray(v2)
    np.testing.assert_allclose(gram, np.eye(k), atol=1e-4)
